"""L2: the DVFO collaborative-inference model in JAX (build-time only).

This module defines, trains (on a synthetic structured dataset — the
image's offline sandbox cannot download CIFAR-100/ImageNet, see DESIGN.md
§Substitutions) and exposes for AOT lowering:

* ``extractor``     — conv feature extractor + SCAM: image → feature maps,
                      channel attention M_c, spatial attention M_s,
                      per-channel importance distribution x ~ p(a).
* ``local_head``    — edge-side DNN over the top-k primary-importance
                      channels (selected by a channel mask supplied at
                      runtime by the rust coordinator).
* ``remote_head``   — cloud-side DNN over the remaining channels ("first
                      convolutional layer removed" relative to the
                      benchmark DNN, per paper §6.2.1 — it consumes
                      feature maps, not images).
* ``offload_prep``  — int8 quantize→dequantize of the masked offload
                      features (what the cloud actually sees after the
                      wire).
* ``fusion``        — λ-weighted summation of the two logit vectors.
* ``dqn_q``         — the DQN Q-network MLP (3 hidden layers, 128/64/32
                      units, paper §6.1) with *weights as arguments* so
                      the rust DQN agent can run policy inference through
                      PJRT with the weights it trained.

Training uses the pure-jnp references (kernels/ref.py); the lowered
inference artifacts use the Pallas kernels (kernels/*.py). The two are
allclose-verified against each other in python/tests, so there is no
train/serve skew.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fusion as kfusion
from .kernels import quantize as kquant
from .kernels import ref
from .kernels import scam as kscam

# ---------------------------------------------------------------- config --
IMG_SHAPE = (3, 32, 32)      # CHW input image
FEAT_C = 16                  # feature-map channels after the stem
FEAT_HW = 16                 # feature-map spatial size after stride-2 stem
NUM_CLASSES = 8
SCAM_REDUCTION = 4           # channel-MLP bottleneck ratio r

DQN_STATE_DIM = 8            # base featurization, rust Obs::features();
                             # the queue-aware multi-stream variant uses
                             # 10 (Obs::features_ext) but is not lowered
                             # to artifacts yet
DQN_HIDDEN = (128, 64, 32)   # paper §6.1


@dataclasses.dataclass
class Params:
    """All trainable parameters of the collaborative model."""
    stem_w: jnp.ndarray      # (FEAT_C, 3, 3, 3)    conv, stride 2
    stem_b: jnp.ndarray      # (FEAT_C,)
    scam_w1: jnp.ndarray     # (FEAT_C, FEAT_C // r)
    scam_b1: jnp.ndarray
    scam_w2: jnp.ndarray     # (FEAT_C // r, FEAT_C)
    scam_b2: jnp.ndarray
    scam_cw: jnp.ndarray     # (2, 3, 3)
    scam_cb: jnp.ndarray     # ()
    local_w: jnp.ndarray     # (FEAT_C*16, NUM_CLASSES)  dense over 4x4 pool
    local_b: jnp.ndarray
    rem_cw: jnp.ndarray      # (32, FEAT_C, 3, 3)        cloud conv
    rem_cb: jnp.ndarray      # (32,)
    rem_w: jnp.ndarray       # (32*16, NUM_CLASSES)
    rem_b: jnp.ndarray

    def tree(self) -> list[jnp.ndarray]:
        return [getattr(self, f.name) for f in dataclasses.fields(self)]


jax.tree_util.register_pytree_node(
    Params,
    lambda p: (p.tree(), None),
    lambda _, leaves: Params(*leaves),
)


def init_params(key: jax.Array) -> Params:
    ks = jax.random.split(key, 16)
    r = FEAT_C // SCAM_REDUCTION

    def glorot(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) / np.sqrt(fan_in)

    return Params(
        stem_w=glorot(ks[0], (FEAT_C, 3, 3, 3), 27),
        stem_b=jnp.zeros((FEAT_C,)),
        scam_w1=glorot(ks[1], (FEAT_C, r), FEAT_C),
        scam_b1=jnp.zeros((r,)),
        scam_w2=glorot(ks[2], (r, FEAT_C), r),
        scam_b2=jnp.zeros((FEAT_C,)),
        scam_cw=glorot(ks[3], (2, 3, 3), 18),
        scam_cb=jnp.zeros(()),
        local_w=glorot(ks[4], (FEAT_C * 16, NUM_CLASSES), FEAT_C * 16),
        local_b=jnp.zeros((NUM_CLASSES,)),
        rem_cw=glorot(ks[5], (32, FEAT_C, 3, 3), FEAT_C * 9),
        rem_cb=jnp.zeros((32,)),
        rem_w=glorot(ks[6], (32 * 16, NUM_CLASSES), 32 * 16),
        rem_b=jnp.zeros((NUM_CLASSES,)),
    )


# ----------------------------------------------------------------- model --
def _conv(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """NCHW conv with SAME padding."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def extractor_fwd(p: Params, img: jnp.ndarray, *, use_pallas: bool):
    """image (N,3,32,32) → (features (N,C,h,w), mc (N,C), ms (N,h,w),
    importance (N,C)). Batched; SCAM applied per-sample."""
    feat = jax.nn.relu(_conv(img, p.stem_w, 2)
                       + p.stem_b[None, :, None, None])

    if use_pallas:
        def one(f):
            out, mc, ms = kscam.scam(f, p.scam_w1, p.scam_b1, p.scam_w2,
                                     p.scam_b2, p.scam_cw, p.scam_cb)
            return out, mc, ms, kscam.importance(out)
        # batch is 1 at lowering time; avoid vmap over interpret-mode pallas
        outs = [one(feat[i]) for i in range(feat.shape[0])]
        stack = lambda i: jnp.stack([o[i] for o in outs])  # noqa: E731
        return stack(0), stack(1), stack(2), stack(3)

    def one_ref(f):
        out, mc, ms = ref.scam(f, p.scam_w1, p.scam_b1, p.scam_w2,
                               p.scam_b2, p.scam_cw, p.scam_cb)
        return out, mc, ms, ref.importance(out)

    return jax.vmap(one_ref)(feat)


def _pool4(x: jnp.ndarray) -> jnp.ndarray:
    """(N, C, 16, 16) → (N, C*16) via 4x4 average pooling + flatten —
    keeps coarse spatial structure (a plain GAP collapses it and the
    synthetic classes become indistinguishable)."""
    n, c, h, w = x.shape
    p = x.reshape(n, c, 4, h // 4, 4, w // 4).mean(axis=(3, 5))
    return p.reshape(n, c * 16)


def local_head_fwd(p: Params, feat: jnp.ndarray,
                   mask: jnp.ndarray) -> jnp.ndarray:
    """Edge head: channel-mask → 4x4 avg-pool → dense.

    feat (N,C,h,w), mask (C,) with 1 = kept locally. Deliberately tiny —
    the edge device keeps only the top-k primary-importance channels and a
    shallow classifier (paper Fig. 4 'Local DNN')."""
    fm = feat * mask[None, :, None, None]
    return _pool4(fm) @ p.local_w + p.local_b


def remote_head_fwd(p: Params, feat: jnp.ndarray,
                    mask: jnp.ndarray) -> jnp.ndarray:
    """Cloud head: conv → relu → GAP → dense over the offloaded channels.

    Consumes feature maps (benchmark DNN minus its first conv, §6.2.1);
    deeper than the local head because the cloud has abundant compute."""
    fm = feat * mask[None, :, None, None]
    h = jax.nn.relu(_conv(fm, p.rem_cw, 1) + p.rem_cb[None, :, None, None])
    return _pool4(h) @ p.rem_w + p.rem_b


def offload_prep_fwd(feat: jnp.ndarray, inv_mask: jnp.ndarray,
                     *, use_pallas: bool) -> jnp.ndarray:
    """What the cloud sees: masked secondary-importance features after the
    int8 quantize→wire→dequantize round trip."""
    fm = feat * inv_mask[None, :, None, None]
    if use_pallas:
        return jnp.stack([kquant.quant_roundtrip(fm[i])
                          for i in range(fm.shape[0])])
    return jax.vmap(ref.quant_roundtrip)(fm)


def fusion_fwd(local_logits: jnp.ndarray, remote_logits: jnp.ndarray,
               lam: jnp.ndarray, *, use_pallas: bool) -> jnp.ndarray:
    if use_pallas:
        return kfusion.weighted_fusion(local_logits, remote_logits, lam)
    return ref.weighted_fusion(local_logits, remote_logits, lam)


def collaborative_fwd(p: Params, img: jnp.ndarray, mask: jnp.ndarray,
                      lam: jnp.ndarray, *, use_pallas: bool = False):
    """Full edge-cloud pipeline for a given channel split. Returns fused
    logits (N, NUM_CLASSES)."""
    feat, _, _, _ = extractor_fwd(p, img, use_pallas=use_pallas)
    loc = local_head_fwd(p, feat, mask)
    dq = offload_prep_fwd(feat, 1.0 - mask, use_pallas=use_pallas)
    rem = remote_head_fwd(p, dq, 1.0 - mask)
    return fusion_fwd(loc, rem, lam, use_pallas=use_pallas)


def topk_mask(importance: jnp.ndarray, k: int) -> jnp.ndarray:
    """1.0 on the k most important channels (ties broken by index)."""
    idx = jnp.argsort(-importance)
    keep = idx[:k]
    return jnp.zeros_like(importance).at[keep].set(1.0)


# ------------------------------------------------------------ DQN Q-net ---
def dqn_q_fwd(state: jnp.ndarray, w1, b1, w2, b2, w3, b3, w4, b4):
    """Q-network forward: state (N,S) → Q-values (N,A).

    Three hidden layers of 128/64/32 relu units (paper §6.1). Weights are
    *arguments*, not constants: the rust agent trains them and feeds them
    into this PJRT artifact for hot-path policy inference."""
    h = jax.nn.relu(state @ w1 + b1)
    h = jax.nn.relu(h @ w2 + b2)
    h = jax.nn.relu(h @ w3 + b3)
    return h @ w4 + b4


def dqn_weight_shapes(state_dim: int, action_dim: int):
    dims = (state_dim,) + DQN_HIDDEN + (action_dim,)
    shapes = []
    for i in range(len(dims) - 1):
        shapes.append((dims[i], dims[i + 1]))
        shapes.append((dims[i + 1],))
    return shapes


# ------------------------------------------------- synthetic dataset ------
TEMPLATE_SEED = 42  # class identity is global, not per-dataset-draw


def class_templates() -> jnp.ndarray:
    """The fixed class templates (shared by train and test draws)."""
    kt = jax.random.PRNGKey(TEMPLATE_SEED)
    templates = jax.random.normal(kt, (NUM_CLASSES,) + IMG_SHAPE)
    # low-pass the templates so classes differ in coarse structure
    return jax.vmap(lambda t: jax.image.resize(
        jax.image.resize(t, (3, 8, 8), "linear"), IMG_SHAPE, "linear"))(
            templates)


def make_dataset(key: jax.Array, n: int, noise: float = 1.5):
    """Structured Gaussian-mixture images: each class has a fixed random
    low-frequency template; samples are template + scaled noise. Hard
    enough that the untrained model is at chance and a trained one is
    well above it — mirroring the CIFAR-100 role in the paper's Table 4."""
    _, kl, kn = jax.random.split(key, 3)
    templates = class_templates()
    labels = jax.random.randint(kl, (n,), 0, NUM_CLASSES)
    imgs = templates[labels] + noise * jax.random.normal(
        kn, (n,) + IMG_SHAPE)
    return imgs.astype(jnp.float32), labels


# ------------------------------------------------------------- training ---
def _xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def loss_fn(p: Params, img, labels, mask, lam):
    """Joint loss: fused prediction + auxiliary per-head terms so both
    heads stay usable stand-alone (needed for Edge-only / Cloud-only
    baselines)."""
    feat, _, _, _ = extractor_fwd(p, img, use_pallas=False)
    loc = local_head_fwd(p, feat, mask)
    dq = offload_prep_fwd(feat, 1.0 - mask, use_pallas=False)
    rem = remote_head_fwd(p, dq, 1.0 - mask)
    fused = ref.weighted_fusion(loc, rem, lam)
    full_loc = local_head_fwd(p, feat, jnp.ones_like(mask))
    full_rem = remote_head_fwd(p, feat, jnp.ones_like(mask))
    return (_xent(fused, labels) + 0.3 * _xent(full_loc, labels)
            + 0.3 * _xent(full_rem, labels))


def train(key: jax.Array, steps: int = 400, batch: int = 64,
          lr: float = 3e-3, verbose: bool = False) -> Params:
    """Adam training over random channel splits (feature-dropout style, so
    any runtime top-k/ξ split the coordinator picks works)."""
    kp, kd = jax.random.split(key)
    p = init_params(kp)
    imgs, labels = make_dataset(kd, 4096)

    flat = p.tree()
    m = [jnp.zeros_like(t) for t in flat]
    v = [jnp.zeros_like(t) for t in flat]
    names = [f.name for f in dataclasses.fields(Params)]

    @jax.jit
    def step(flat, m, v, i, img, lab, mask, lam):
        p = Params(**dict(zip(names, flat)))
        loss, grads = jax.value_and_grad(
            lambda pp: loss_fn(pp, img, lab, mask, lam))(p)
        g = grads.tree()
        b1, b2, eps = 0.9, 0.999, 1e-8
        out_f, out_m, out_v = [], [], []
        for t, gt, mt, vt in zip(flat, g, m, v):
            mt = b1 * mt + (1 - b1) * gt
            vt = b2 * vt + (1 - b2) * gt * gt
            mh = mt / (1 - b1 ** i)
            vh = vt / (1 - b2 ** i)
            out_f.append(t - lr * mh / (jnp.sqrt(vh) + eps))
            out_m.append(mt)
            out_v.append(vt)
        return out_f, out_m, out_v, loss

    rng = np.random.default_rng(7)
    for i in range(1, steps + 1):
        sel = rng.integers(0, imgs.shape[0], batch)
        k = int(rng.integers(FEAT_C // 4, 3 * FEAT_C // 4 + 1))
        mask = np.zeros(FEAT_C, np.float32)
        mask[rng.permutation(FEAT_C)[:k]] = 1.0
        lam = jnp.float32(rng.uniform(0.3, 0.7))
        flat, m, v, loss = step(flat, m, v, jnp.float32(i),
                                imgs[sel], labels[sel],
                                jnp.asarray(mask), lam)
        if verbose and i % 100 == 0:
            print(f"step {i:4d} loss {float(loss):.4f}")
    return Params(**dict(zip(names, flat)))


def evaluate(p: Params, imgs, labels, mask, lam) -> float:
    logits = collaborative_fwd(p, imgs, mask, lam, use_pallas=False)
    return float((logits.argmax(-1) == labels).mean())


def evaluate_edge_only(p: Params, imgs, labels) -> float:
    feat, _, _, _ = extractor_fwd(p, imgs, use_pallas=False)
    logits = local_head_fwd(p, feat, jnp.ones((FEAT_C,)))
    return float((logits.argmax(-1) == labels).mean())
