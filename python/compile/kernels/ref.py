"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact functional twin here; pytest
asserts allclose between the two over a hypothesis-driven sweep of shapes.
These references are also what the L2 model uses when ``use_pallas=False``
(e.g. while debugging lowering issues).
"""
from __future__ import annotations

import jax.numpy as jnp


def _sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    return 1.0 / (1.0 + jnp.exp(-x))


# ---------------------------------------------------------------- SCAM ----
def channel_pool(f: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Global average + max pooling over the spatial axes.

    f: (C, H, W) -> (avg, max) each (C,)
    """
    return f.mean(axis=(1, 2)), f.max(axis=(1, 2))


def channel_mlp(avg: jnp.ndarray, mx: jnp.ndarray,
                w1: jnp.ndarray, b1: jnp.ndarray,
                w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Shared two-layer MLP of the channel attention (Eq. 16).

    M_c = sigmoid(MLP(avg) + MLP(max)),  MLP(x) = relu(x@W1+b1)@W2+b2
    avg/mx: (C,), w1: (C, R), w2: (R, C) -> (C,)
    """
    def mlp(x):
        h = jnp.maximum(x @ w1 + b1, 0.0)
        return h @ w2 + b2

    return _sigmoid(mlp(avg) + mlp(mx))


def spatial_attention(f: jnp.ndarray, conv_w: jnp.ndarray,
                      conv_b: jnp.ndarray) -> jnp.ndarray:
    """Spatial attention map (Eq. 17).

    M_s = sigmoid(Conv3x3([avgpool_c(F); maxpool_c(F)]))
    f: (C, H, W); conv_w: (2, 3, 3) (in-channel, kh, kw); conv_b: ()
    returns (H, W).
    """
    avg = f.mean(axis=0)
    mx = f.max(axis=0)
    stacked = jnp.stack([avg, mx], axis=0)          # (2, H, W)
    padded = jnp.pad(stacked, ((0, 0), (1, 1), (1, 1)))
    h, w = f.shape[1], f.shape[2]
    out = jnp.zeros((h, w), f.dtype)
    for c in range(2):
        for i in range(3):
            for j in range(3):
                out = out + conv_w[c, i, j] * padded[c, i:i + h, j:j + w]
    return _sigmoid(out + conv_b)


def scam_apply(f: jnp.ndarray, mc: jnp.ndarray, ms: jnp.ndarray) -> jnp.ndarray:
    """Sequential channel-then-spatial application (Eq. 18).

    F_in = M_c ⊗ F ;  F_out = M_s ⊗ F_in
    """
    f_in = f * mc[:, None, None]
    return f_in * ms[None, :, :]


def scam(f, w1, b1, w2, b2, conv_w, conv_b):
    """Full SCAM forward: returns (F_out, M_c, M_s)."""
    avg, mx = channel_pool(f)
    mc = channel_mlp(avg, mx, w1, b1, w2, b2)
    ms = spatial_attention(f, conv_w, conv_b)
    return scam_apply(f, mc, ms), mc, ms


def importance(f_out: jnp.ndarray) -> jnp.ndarray:
    """Per-channel importance distribution x ~ p(a): normalized attention
    mass per channel (sums to 1)."""
    mass = jnp.abs(f_out).sum(axis=(1, 2))
    return mass / jnp.maximum(mass.sum(), 1e-12)


# ----------------------------------------------------------- quantization --
def absmax(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(jnp.abs(x))


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Symmetric per-tensor int8 quantization: q = clip(round(x/s), ±127)."""
    q = jnp.round(x / jnp.maximum(scale, 1e-12))
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quant_roundtrip(x: jnp.ndarray) -> jnp.ndarray:
    """quantize → dequantize, the int8 compression used for offloaded
    secondary-importance feature maps (paper §5.2)."""
    scale = absmax(x) / 127.0
    return dequantize_int8(quantize_int8(x, scale), scale)


# ----------------------------------------------------------------- fusion --
def weighted_fusion(local_logits: jnp.ndarray, remote_logits: jnp.ndarray,
                    lam: jnp.ndarray) -> jnp.ndarray:
    """Point-to-point weighted summation fusion (paper §5.3):
    out = λ·local + (1−λ)·remote."""
    return lam * local_logits + (1.0 - lam) * remote_logits
