"""L1 Pallas kernel for weighted-summation fusion (paper §5.3).

out = λ · local_logits + (1 − λ) · remote_logits

The paper's key design point: point-to-point weighted summation keeps the
two logit vectors dimension-aligned (unlike FC/conv fusion layers, Table 4)
and is a single fused VPU multiply-add — negligible edge-side overhead.
λ arrives as a (1, 1) operand so the same compiled artifact serves every
user-configured λ without recompilation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _fusion_kernel(a_ref, b_ref, lam_ref, out_ref):
    lam = lam_ref[0, 0]
    out_ref[...] = lam * a_ref[...] + (1.0 - lam) * b_ref[...]


def weighted_fusion(local_logits: jnp.ndarray, remote_logits: jnp.ndarray,
                    lam: jnp.ndarray) -> jnp.ndarray:
    """λ·local + (1−λ)·remote, elementwise over an arbitrary shape."""
    shape = local_logits.shape
    a = local_logits.reshape(1, -1)
    b = remote_logits.reshape(1, -1)
    out = pl.pallas_call(
        _fusion_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, local_logits.dtype),
        interpret=INTERPRET,
    )(a, b, jnp.asarray(lam, local_logits.dtype).reshape(1, 1))
    return out.reshape(shape)
