"""L1 Pallas kernels for symmetric per-tensor int8 quantization.

Paper §5.2: secondary-importance feature maps are compressed from f32 to
int8 before offloading (4x wire-size reduction; the paper's "precision
quantization" motivated by SPINN). TPU adaptation: the absmax reduction
accumulates across sequential grid steps into a revisited (1, 1) block;
quantize/dequantize are elementwise VPU ops (round/clip/scale), tiled to
VMEM-sized blocks — no warp shuffles or atomics as a CUDA version would
use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _tile(n: int, target: int) -> int:
    t = min(n, target)
    while n % t:
        t -= 1
    return t


# ------------------------------------------------------------------------
def _absmax_kernel(x_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, 0] = jnp.maximum(out_ref[0, 0], jnp.abs(x_ref[...]).max())


def absmax(x: jnp.ndarray, block: int = 4096) -> jnp.ndarray:
    """max|x| over a flattened tensor, tiled; returns a scalar."""
    flat = x.reshape(1, -1)
    n = flat.shape[1]
    nb = _tile(n, block)
    out = pl.pallas_call(
        _absmax_kernel,
        grid=(n // nb,),
        in_specs=[pl.BlockSpec((1, nb), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        interpret=INTERPRET,
    )(flat)
    return out[0, 0]


# ------------------------------------------------------------------------
def _quantize_kernel(x_ref, scale_ref, q_ref):
    s = jnp.maximum(scale_ref[0, 0], 1e-12)
    q = jnp.round(x_ref[...] / s)
    q_ref[...] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def quantize_int8(x: jnp.ndarray, scale: jnp.ndarray,
                  block: int = 4096) -> jnp.ndarray:
    """q = clip(round(x / scale), ±127) as int8; shape-preserving."""
    shape = x.shape
    flat = x.reshape(1, -1)
    n = flat.shape[1]
    nb = _tile(n, block)
    q = pl.pallas_call(
        _quantize_kernel,
        grid=(n // nb,),
        in_specs=[
            pl.BlockSpec((1, nb), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int8),
        interpret=INTERPRET,
    )(flat, scale.reshape(1, 1).astype(x.dtype))
    return q.reshape(shape)


# ------------------------------------------------------------------------
def _dequantize_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[0, 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    block: int = 4096) -> jnp.ndarray:
    shape = q.shape
    flat = q.reshape(1, -1)
    n = flat.shape[1]
    nb = _tile(n, block)
    x = pl.pallas_call(
        _dequantize_kernel,
        grid=(n // nb,),
        in_specs=[
            pl.BlockSpec((1, nb), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=INTERPRET,
    )(flat, scale.reshape(1, 1).astype(jnp.float32))
    return x.reshape(shape)


def quant_roundtrip(x: jnp.ndarray) -> jnp.ndarray:
    """quantize → dequantize, as experienced by the cloud-side remote DNN."""
    scale = absmax(x) / 127.0
    return dequantize_int8(quantize_int8(x, scale), scale)
