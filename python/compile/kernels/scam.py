"""L1 Pallas kernels for the Spatial-Channel Attention Module (SCAM).

Paper §5.2 (Eqs. 16-18). TPU-idiom adaptation of the CUDA original
(DESIGN.md §Hardware-Adaptation):

* channel attention — the (H, W) reduction is tiled over *channel* blocks
  sized for VMEM with ``BlockSpec``; the shared MLP is expressed as two
  small matmuls so it lands on the MXU.
* spatial attention — the channel reduction accumulates across sequential
  grid steps into a single (H, W) output block (TPU grid steps are
  sequential, so read-modify-write on a revisited output block is legal);
  the 3x3 convolution is expressed as nine shifted vector FMAs on the VPU
  instead of the warp-tiled im2col a GPU kernel would use.

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU perf is estimated analytically in
DESIGN.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU backend: must stay True (see module docstring).


def _tile(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``target`` (≥ 1)."""
    t = min(n, target)
    while n % t:
        t -= 1
    return t


# ------------------------------------------------------------------------
# channel pooling: (C, H, W) -> avg (C,), max (C,)
# ------------------------------------------------------------------------
def _channel_pool_kernel(f_ref, avg_ref, max_ref):
    blk = f_ref[...]                       # (Cb, H, W) in VMEM
    avg_ref[...] = blk.mean(axis=(1, 2))
    max_ref[...] = blk.max(axis=(1, 2))


def channel_pool(f: jnp.ndarray, block_c: int = 8):
    """Global average + max pool over spatial axes, tiled over channels."""
    c, h, w = f.shape
    cb = _tile(c, block_c)
    grid = (c // cb,)
    return pl.pallas_call(
        _channel_pool_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((cb, h, w), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((cb,), lambda i: (i,)),
            pl.BlockSpec((cb,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((c,), f.dtype),
            jax.ShapeDtypeStruct((c,), f.dtype),
        ),
        interpret=INTERPRET,
    )(f)


# ------------------------------------------------------------------------
# channel MLP: M_c = sigmoid(MLP(avg) + MLP(max))  (Eq. 16)
# ------------------------------------------------------------------------
def _channel_mlp_kernel(avg_ref, max_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                        mc_ref):
    w1 = w1_ref[...]
    w2 = w2_ref[...]
    b1 = b1_ref[...]
    b2 = b2_ref[...]

    def mlp(x):
        # (1, C) @ (C, R) and (1, R) @ (R, C): MXU-shaped matmuls.
        h = jnp.maximum(jnp.dot(x, w1, preferred_element_type=jnp.float32)
                        + b1, 0.0)
        return jnp.dot(h, w2, preferred_element_type=jnp.float32) + b2

    s = mlp(avg_ref[...].reshape(1, -1)) + mlp(max_ref[...].reshape(1, -1))
    mc_ref[...] = (1.0 / (1.0 + jnp.exp(-s))).reshape(-1)


def channel_mlp(avg, mx, w1, b1, w2, b2):
    """Shared-MLP channel attention. Single grid step: C and R are small
    (≤ a few hundred), so both weight matrices fit VMEM comfortably."""
    (c,) = avg.shape
    return pl.pallas_call(
        _channel_mlp_kernel,
        out_shape=jax.ShapeDtypeStruct((c,), avg.dtype),
        interpret=INTERPRET,
    )(avg, mx, w1, b1, w2, b2)


# ------------------------------------------------------------------------
# spatial pooling: (C, H, W) -> stacked (2, H, W) [channel-avg; channel-max]
# accumulated across channel-tile grid steps.
# ------------------------------------------------------------------------
def _spatial_pool_kernel(f_ref, sum_ref, max_ref, *, n_steps: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        max_ref[...] = jnp.full_like(max_ref, -jnp.inf)

    blk = f_ref[...]                              # (Cb, H, W)
    sum_ref[...] += blk.sum(axis=0)
    max_ref[...] = jnp.maximum(max_ref[...], blk.max(axis=0))


def spatial_pool(f: jnp.ndarray, block_c: int = 8):
    """Channel-wise avg/max pooling, accumulating over channel tiles."""
    c, h, w = f.shape
    cb = _tile(c, block_c)
    n = c // cb
    s, m = pl.pallas_call(
        functools.partial(_spatial_pool_kernel, n_steps=n),
        grid=(n,),
        in_specs=[pl.BlockSpec((cb, h, w), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((h, w), lambda i: (0, 0)),  # revisited block
            pl.BlockSpec((h, w), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((h, w), f.dtype),
            jax.ShapeDtypeStruct((h, w), f.dtype),
        ),
        interpret=INTERPRET,
    )(f)
    return s / jnp.asarray(c, f.dtype), m


# ------------------------------------------------------------------------
# 3x3 conv + sigmoid over the stacked pooled maps (Eq. 17)
# ------------------------------------------------------------------------
def _spatial_conv_kernel(stacked_ref, w_ref, b_ref, ms_ref):
    x = stacked_ref[...]                          # (2, H, W)
    w = w_ref[...]                                # (2, 3, 3)
    b = b_ref[...]                                # (1, 1)
    _, h, wid = x.shape
    padded = jnp.pad(x, ((0, 0), (1, 1), (1, 1)))
    acc = jnp.zeros((h, wid), x.dtype)
    # nine shifted FMAs per input channel: pure VPU work, no gathers.
    for ci in range(2):
        for i in range(3):
            for j in range(3):
                acc = acc + w[ci, i, j] * padded[ci, i:i + h, j:j + wid]
    acc = acc + b[0, 0]
    ms_ref[...] = 1.0 / (1.0 + jnp.exp(-acc))


def spatial_conv(stacked: jnp.ndarray, conv_w: jnp.ndarray,
                 conv_b: jnp.ndarray):
    """sigmoid(Conv3x3([avg; max])): full-block — (2, H, W) fits VMEM for
    every feature-map size this model produces (≤ 2·64·64·4 B = 32 KiB)."""
    _, h, w = stacked.shape
    return pl.pallas_call(
        _spatial_conv_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), stacked.dtype),
        interpret=INTERPRET,
    )(stacked, conv_w, conv_b.reshape(1, 1))


def spatial_attention(f: jnp.ndarray, conv_w: jnp.ndarray,
                      conv_b: jnp.ndarray, block_c: int = 8):
    avg, mx = spatial_pool(f, block_c=block_c)
    return spatial_conv(jnp.stack([avg, mx], axis=0), conv_w, conv_b)


# ------------------------------------------------------------------------
# apply: F_out = M_s ⊗ (M_c ⊗ F)   (Eq. 18)
# ------------------------------------------------------------------------
def _apply_kernel(f_ref, mc_ref, ms_ref, out_ref):
    f = f_ref[...]                                # (Cb, H, W)
    mc = mc_ref[...]                              # (Cb,)
    ms = ms_ref[...]                              # (H, W)
    out_ref[...] = f * mc[:, None, None] * ms[None, :, :]


def scam_apply(f: jnp.ndarray, mc: jnp.ndarray, ms: jnp.ndarray,
               block_c: int = 8):
    c, h, w = f.shape
    cb = _tile(c, block_c)
    return pl.pallas_call(
        _apply_kernel,
        grid=(c // cb,),
        in_specs=[
            pl.BlockSpec((cb, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb,), lambda i: (i,)),
            pl.BlockSpec((h, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((cb, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h, w), f.dtype),
        interpret=INTERPRET,
    )(f, mc, ms)


# ------------------------------------------------------------------------
# full SCAM forward
# ------------------------------------------------------------------------
def scam(f, w1, b1, w2, b2, conv_w, conv_b, block_c: int = 8):
    """Full SCAM (channel-first, per CBAM ablation cited in the paper).

    Returns (F_out, M_c, M_s)."""
    avg, mx = channel_pool(f, block_c=block_c)
    mc = channel_mlp(avg, mx, w1, b1, w2, b2)
    ms = spatial_attention(f, conv_w, conv_b, block_c=block_c)
    return scam_apply(f, mc, ms, block_c=block_c), mc, ms


def importance(f_out: jnp.ndarray, block_c: int = 8) -> jnp.ndarray:
    """Per-channel importance x ~ p(a): |F_out| mass per channel,
    normalized. The per-channel reduction is a Pallas kernel; the final
    C-length normalization is a trivial jnp epilogue."""
    c, h, w = f_out.shape
    cb = _tile(c, block_c)

    def _mass_kernel(f_ref, m_ref):
        m_ref[...] = jnp.abs(f_ref[...]).sum(axis=(1, 2))

    mass = pl.pallas_call(
        _mass_kernel,
        grid=(c // cb,),
        in_specs=[pl.BlockSpec((cb, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((cb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), f_out.dtype),
        interpret=INTERPRET,
    )(f_out)
    return mass / jnp.maximum(mass.sum(), 1e-12)
