"""AOT pipeline: train the L2 model, lower every entry point to HLO text.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (all under ``artifacts/``):
  extractor.hlo.txt      image → (features, M_c, M_s, importance)
  local_head.hlo.txt     (features, mask) → local logits
  offload_prep.hlo.txt   (features, inv_mask) → int8-roundtripped features
  remote_head.hlo.txt    (features, mask) → remote logits
  fusion.hlo.txt         (local, remote, λ) → fused logits
  collaborative.hlo.txt  (image, mask, λ) → fused logits  (single-call e2e)
  dqn_q.hlo.txt          (state, w1..b4) → Q-values (weights are inputs!)
  testset.bin            256 images f32 + labels u32 (raw little-endian)
  manifest.json          shapes, dtypes, measured accuracies, dims

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
Python never runs again after this — the rust binary is self-contained.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

TESTSET_N = 256


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True so the
    rust side always unwraps a tuple, uniformly).

    `print_large_constants=True` is load-bearing: the default printer
    ELIDES big dense constants as `constant({...})`, and the rust-side
    text parser silently fills them with zeros — which wipes out every
    trained weight baked into the artifact.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided constants"
    return text


def lower(fn, *args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*args))


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--levels", type=int, default=10,
                    help="frequency levels per unit in the DQN action head")
    ap.add_argument("--xi-levels", type=int, default=11,
                    help="offload-proportion levels in the action head")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()

    # ---------------------------------------------------------- training --
    key = jax.random.PRNGKey(args.seed)
    params = M.train(key, steps=args.train_steps, verbose=args.verbose)
    print(f"[aot] trained model in {time.time() - t0:.1f}s")

    # held-out accuracy bookkeeping for the manifest
    kt = jax.random.PRNGKey(args.seed + 1)
    timgs, tlabels = M.make_dataset(kt, TESTSET_N)
    _, _, _, imp = M.extractor_fwd(params, timgs, use_pallas=False)
    mean_imp = np.asarray(imp.mean(axis=0))
    acc = {"edge_only": M.evaluate_edge_only(params, timgs, tlabels)}
    for k in (4, 8, 12):
        mask = M.topk_mask(jnp.asarray(mean_imp), k)
        acc[f"collab_k{k}"] = M.evaluate(params, timgs, tlabels, mask,
                                         jnp.float32(0.5))
    print(f"[aot] accuracies: {acc}")

    # ------------------------------------------------------------ lowering --
    c, hw = M.FEAT_C, M.FEAT_HW
    img_s = spec((1,) + M.IMG_SHAPE)
    feat_s = spec((1, c, hw, hw))
    mask_s = spec((c,))
    logit_s = spec((1, M.NUM_CLASSES))
    lam_s = spec((1, 1))

    artifacts: dict[str, dict] = {}

    def emit(name: str, fn, *specs, outputs: list[str]):
        text = lower(fn, *specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                       for s in specs],
            "outputs": outputs,
        }
        print(f"[aot] {name}: {len(text)} chars")

    emit("extractor",
         lambda img: M.extractor_fwd(params, img, use_pallas=True),
         img_s, outputs=["features", "mc", "ms", "importance"])

    emit("local_head",
         lambda feat, mask: M.local_head_fwd(params, feat, mask),
         feat_s, mask_s, outputs=["local_logits"])

    emit("offload_prep",
         lambda feat, inv: M.offload_prep_fwd(feat, inv, use_pallas=True),
         feat_s, mask_s, outputs=["dequantized_features"])

    emit("remote_head",
         lambda feat, mask: M.remote_head_fwd(params, feat, mask),
         feat_s, mask_s, outputs=["remote_logits"])

    emit("fusion",
         lambda a, b, lam: M.fusion_fwd(a, b, lam, use_pallas=True),
         logit_s, logit_s, lam_s, outputs=["fused_logits"])

    emit("collaborative",
         lambda img, mask, lam: M.collaborative_fwd(
             params, img, mask, lam, use_pallas=True),
         img_s, mask_s, lam_s, outputs=["fused_logits"])

    # DQN Q-net: weights as runtime inputs (trained by the rust agent).
    action_dim = 3 * args.levels + args.xi_levels
    wshapes = M.dqn_weight_shapes(M.DQN_STATE_DIM, action_dim)
    state_s = spec((1, M.DQN_STATE_DIM))
    wspecs = [spec(s) for s in wshapes]
    emit("dqn_q",
         lambda s, *w: M.dqn_q_fwd(s, *w),
         state_s, *wspecs, outputs=["q_values"])

    # ---------------------------------------------------------- testset ----
    test_path = os.path.join(args.out_dir, "testset.bin")
    with open(test_path, "wb") as f:
        f.write(np.asarray(timgs, np.float32).tobytes())
        f.write(np.asarray(tlabels, np.uint32).tobytes())

    # expected fused logits for the first test image (bit-exactness check
    # for the rust runtime, mask = top-8 channels, λ = 0.5)
    mask8 = M.topk_mask(jnp.asarray(mean_imp), 8)
    probe_logits = M.collaborative_fwd(
        params, timgs[:1], mask8, jnp.float32(0.5), use_pallas=False)

    manifest = {
        "version": 1,
        "generated_unix": int(time.time()),
        "model": {
            "img_shape": list(M.IMG_SHAPE),
            "feat_channels": c,
            "feat_hw": hw,
            "num_classes": M.NUM_CLASSES,
        },
        "dqn": {
            "state_dim": M.DQN_STATE_DIM,
            "hidden": list(M.DQN_HIDDEN),
            "action_dim": action_dim,
            "freq_levels": args.levels,
            "xi_levels": args.xi_levels,
            "weight_shapes": [list(s) for s in wshapes],
        },
        "testset": {
            "file": "testset.bin",
            "count": TESTSET_N,
            "img_f32_count": TESTSET_N * int(np.prod(M.IMG_SHAPE)),
        },
        "accuracy": acc,
        "mean_importance": [float(x) for x in mean_imp],
        "probe": {
            "mask_topk": 8,
            "lambda": 0.5,
            "expected_logits": [float(x) for x in np.asarray(probe_logits[0])],
        },
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t0:.1f}s → {args.out_dir}")


if __name__ == "__main__":
    main()
