"""L2 contracts: shapes, pallas-vs-ref parity of the full model, dataset
statistics, DQN Q-net shape algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    return M.make_dataset(jax.random.PRNGKey(1), 4)


def test_extractor_shapes(params, batch):
    imgs, _ = batch
    feat, mc, ms, imp = M.extractor_fwd(params, imgs, use_pallas=False)
    n = imgs.shape[0]
    assert feat.shape == (n, M.FEAT_C, M.FEAT_HW, M.FEAT_HW)
    assert mc.shape == (n, M.FEAT_C)
    assert ms.shape == (n, M.FEAT_HW, M.FEAT_HW)
    assert imp.shape == (n, M.FEAT_C)
    np.testing.assert_allclose(np.asarray(imp.sum(-1)), 1.0, atol=1e-5)


def test_extractor_pallas_matches_ref(params, batch):
    imgs, _ = batch
    a = M.extractor_fwd(params, imgs[:1], use_pallas=True)
    b = M.extractor_fwd(params, imgs[:1], use_pallas=False)
    for got, want in zip(a, b):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_collaborative_pallas_matches_ref(params, batch):
    imgs, _ = batch
    mask = M.topk_mask(jnp.ones(M.FEAT_C) / M.FEAT_C, 8)
    lam = jnp.float32(0.5)
    got = M.collaborative_fwd(params, imgs[:1], mask, lam, use_pallas=True)
    want = M.collaborative_fwd(params, imgs[:1], mask, lam, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_heads_shapes(params, batch):
    imgs, _ = batch
    feat, _, _, _ = M.extractor_fwd(params, imgs, use_pallas=False)
    mask = jnp.ones(M.FEAT_C)
    assert M.local_head_fwd(params, feat, mask).shape == (4, M.NUM_CLASSES)
    assert M.remote_head_fwd(params, feat, mask).shape == (4, M.NUM_CLASSES)


def test_masked_channels_do_not_leak(params, batch):
    """A head must be invariant to features in channels its mask zeroes."""
    imgs, _ = batch
    feat, _, _, _ = M.extractor_fwd(params, imgs, use_pallas=False)
    mask = M.topk_mask(jnp.arange(M.FEAT_C, dtype=jnp.float32), 8)
    poisoned = feat + 1e3 * (1.0 - mask)[None, :, None, None]
    a = M.local_head_fwd(params, feat, mask)
    b = M.local_head_fwd(params, poisoned, mask)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_topk_mask_selects_k_largest():
    imp = jnp.asarray([0.1, 0.5, 0.05, 0.2, 0.15])
    m = M.topk_mask(imp, 2)
    np.testing.assert_array_equal(np.asarray(m), [0, 1, 0, 1, 0])


def test_dataset_is_reproducible_and_balancedish():
    i1, l1 = M.make_dataset(jax.random.PRNGKey(9), 512)
    i2, l2 = M.make_dataset(jax.random.PRNGKey(9), 512)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_allclose(np.asarray(i1), np.asarray(i2))
    counts = np.bincount(np.asarray(l1), minlength=M.NUM_CLASSES)
    assert counts.min() > 512 // M.NUM_CLASSES // 3


def test_dataset_templates_shared_across_draws():
    """Train/test draws must share class identity (regression test for the
    template-per-key bug)."""
    t1 = M.class_templates()
    t2 = M.class_templates()
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2))


def test_dqn_weight_shapes_chain():
    shapes = M.dqn_weight_shapes(8, 41)
    assert shapes == [(8, 128), (128,), (128, 64), (64,), (64, 32), (32,),
                      (32, 41), (41,)]


def test_dqn_q_fwd_shape():
    shapes = M.dqn_weight_shapes(M.DQN_STATE_DIM, 41)
    ws = [jnp.zeros(s) for s in shapes]
    q = M.dqn_q_fwd(jnp.zeros((1, M.DQN_STATE_DIM)), *ws)
    assert q.shape == (1, 41)


def test_fusion_lambda_blends_logits(params, batch):
    imgs, _ = batch
    mask = M.topk_mask(jnp.arange(M.FEAT_C, dtype=jnp.float32), 8)
    feat, _, _, _ = M.extractor_fwd(params, imgs[:1], use_pallas=False)
    loc = M.local_head_fwd(params, feat, mask)
    rem = M.remote_head_fwd(params, feat, 1.0 - mask)
    mid = M.fusion_fwd(loc, rem, jnp.float32(0.5), use_pallas=False)
    np.testing.assert_allclose(np.asarray(mid), np.asarray((loc + rem) / 2),
                               rtol=1e-6)
