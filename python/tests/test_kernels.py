"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (and block sizes, which exercise the BlockSpec
tiling logic); assert_allclose against kernels/ref.py is THE correctness
signal for the kernels that end up inside the AOT artifacts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fusion as kfusion
from compile.kernels import quantize as kquant
from compile.kernels import ref
from compile.kernels import scam as kscam

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32) * scale


def scam_weights(c: int, r: int, key: int = 3):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    return (
        jax.random.normal(ks[0], (c, r)) * 0.4,
        jax.random.normal(ks[1], (r,)) * 0.1,
        jax.random.normal(ks[2], (r, c)) * 0.4,
        jax.random.normal(ks[3], (c,)) * 0.1,
        jax.random.normal(ks[4], (2, 3, 3)) * 0.4,
        jnp.float32(0.07),
    )


# ------------------------------------------------------------------ SCAM --
@given(c=st.sampled_from([4, 8, 16, 32]),
       h=st.sampled_from([4, 8, 16]),
       blk=st.sampled_from([1, 2, 8, 16]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_channel_pool_matches_ref(c, h, blk, seed):
    f = rand(seed, (c, h, h), 2.0)
    avg_p, max_p = kscam.channel_pool(f, block_c=blk)
    avg_r, max_r = ref.channel_pool(f)
    np.testing.assert_allclose(avg_p, avg_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(max_p, max_r, rtol=1e-5, atol=1e-6)


@given(c=st.sampled_from([4, 8, 16]), r=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_channel_mlp_matches_ref(c, r, seed):
    w1, b1, w2, b2, _, _ = scam_weights(c, r, key=seed % 97)
    avg = rand(seed, (c,))
    mx = rand(seed + 1, (c,))
    got = kscam.channel_mlp(avg, mx, w1, b1, w2, b2)
    want = ref.channel_mlp(avg, mx, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(c=st.sampled_from([4, 8, 16, 32]), h=st.sampled_from([4, 8, 16]),
       blk=st.sampled_from([1, 4, 8]), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_spatial_attention_matches_ref(c, h, blk, seed):
    f = rand(seed, (c, h, h), 2.0)
    _, _, _, _, cw, cb = scam_weights(c, 4, key=seed % 89)
    got = kscam.spatial_attention(f, cw, cb, block_c=blk)
    want = ref.spatial_attention(f, cw, cb)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(c=st.sampled_from([4, 16]), h=st.sampled_from([8, 16]),
       blk=st.sampled_from([2, 8]), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_full_scam_matches_ref(c, h, blk, seed):
    f = rand(seed, (c, h, h), 1.5)
    w1, b1, w2, b2, cw, cb = scam_weights(c, max(c // 4, 1), key=seed % 83)
    out_p, mc_p, ms_p = kscam.scam(f, w1, b1, w2, b2, cw, cb, block_c=blk)
    out_r, mc_r, ms_r = ref.scam(f, w1, b1, w2, b2, cw, cb)
    np.testing.assert_allclose(mc_p, mc_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ms_p, ms_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out_p, out_r, rtol=1e-5, atol=1e-6)


@given(c=st.sampled_from([4, 16]), h=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_importance_is_distribution(c, h, seed):
    f = rand(seed, (c, h, h))
    p = kscam.importance(f)
    np.testing.assert_allclose(p, ref.importance(f), rtol=1e-5, atol=1e-7)
    assert float(p.sum()) == pytest.approx(1.0, abs=1e-5)
    assert float(p.min()) >= 0.0


def test_scam_attention_maps_are_bounded():
    """M_c and M_s are sigmoid outputs: strictly inside (0, 1)."""
    f = rand(11, (16, 16, 16), 3.0)
    w1, b1, w2, b2, cw, cb = scam_weights(16, 4)
    _, mc, ms = kscam.scam(f, w1, b1, w2, b2, cw, cb)
    assert float(mc.min()) > 0.0 and float(mc.max()) < 1.0
    assert float(ms.min()) > 0.0 and float(ms.max()) < 1.0


# ------------------------------------------------------------ quantization --
@given(n=st.sampled_from([16, 100, 4096, 5000]), scale=st.sampled_from(
    [1e-3, 1.0, 100.0]), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_absmax_matches_ref(n, scale, seed):
    x = rand(seed, (n,), scale)
    np.testing.assert_allclose(kquant.absmax(x), ref.absmax(x), rtol=1e-6)


@given(shape=st.sampled_from([(64,), (7, 33), (4, 8, 8)]),
       scale=st.sampled_from([1e-2, 1.0, 10.0]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_quant_roundtrip_matches_ref(shape, scale, seed):
    x = rand(seed, shape, scale)
    got = kquant.quant_roundtrip(x)
    want = ref.quant_roundtrip(x)
    np.testing.assert_allclose(got, want, atol=1e-6)


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_quant_error_bounded_by_half_step(seed):
    """|x - dequant(quant(x))| <= scale/2 for in-range values."""
    x = rand(seed, (256,), 2.0)
    s = float(ref.absmax(x)) / 127.0
    err = np.abs(np.asarray(kquant.quant_roundtrip(x)) - np.asarray(x))
    assert err.max() <= s / 2 + 1e-6


def test_quantize_emits_int8():
    x = rand(5, (32,), 3.0)
    q = kquant.quantize_int8(x, kquant.absmax(x) / 127.0)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127


def test_quantize_zero_tensor_is_safe():
    x = jnp.zeros((64,), jnp.float32)
    out = kquant.quant_roundtrip(x)
    np.testing.assert_allclose(out, x, atol=0)


# ---------------------------------------------------------------- fusion --
@given(n=st.sampled_from([8, 100]), lam=st.sampled_from([0.0, 0.3, 0.5, 1.0]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_fusion_matches_ref(n, lam, seed):
    a = rand(seed, (n,))
    b = rand(seed + 1, (n,))
    got = kfusion.weighted_fusion(a, b, jnp.float32(lam))
    want = ref.weighted_fusion(a, b, jnp.float32(lam))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_fusion_extremes_select_single_source():
    a = rand(1, (16,))
    b = rand(2, (16,))
    np.testing.assert_allclose(
        kfusion.weighted_fusion(a, b, jnp.float32(1.0)), a, atol=1e-7)
    np.testing.assert_allclose(
        kfusion.weighted_fusion(a, b, jnp.float32(0.0)), b, atol=1e-7)
