"""Artifact-level contracts: manifest schema, HLO text loadability (parsed
back through xla_client), testset binary layout. Skipped when artifacts
have not been built yet (run `make artifacts` first)."""
import json
import os
import struct

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


EXPECTED = ["extractor", "local_head", "offload_prep", "remote_head",
            "fusion", "collaborative", "dqn_q"]


def test_manifest_lists_all_artifacts(manifest):
    for name in EXPECTED:
        assert name in manifest["artifacts"], name
        path = os.path.join(ART, manifest["artifacts"][name]["file"])
        assert os.path.getsize(path) > 100


def test_hlo_text_parses(manifest):
    """Each artifact must start with an HLO module header and mention an
    ENTRY computation — the minimal structure the rust-side text parser
    requires."""
    for name in EXPECTED:
        path = os.path.join(ART, manifest["artifacts"][name]["file"])
        text = open(path).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_testset_binary_layout(manifest):
    meta = manifest["testset"]
    n = meta["count"]
    img_f32 = meta["img_f32_count"]
    path = os.path.join(ART, meta["file"])
    size = os.path.getsize(path)
    assert size == img_f32 * 4 + n * 4
    with open(path, "rb") as f:
        imgs = np.frombuffer(f.read(img_f32 * 4), np.float32)
        labels = np.frombuffer(f.read(n * 4), np.uint32)
    assert np.isfinite(imgs).all()
    assert labels.max() < manifest["model"]["num_classes"]


def test_manifest_accuracy_is_sane(manifest):
    acc = manifest["accuracy"]
    # trained model must be far above chance (1/8) on all operating points
    for k, v in acc.items():
        assert v > 0.5, (k, v)


def test_probe_logits_present(manifest):
    probe = manifest["probe"]
    assert len(probe["expected_logits"]) == manifest["model"]["num_classes"]
    assert all(np.isfinite(probe["expected_logits"]))


def test_dqn_dims_consistent(manifest):
    d = manifest["dqn"]
    assert d["action_dim"] == 3 * d["freq_levels"] + d["xi_levels"]
    shapes = [tuple(s) for s in d["weight_shapes"]]
    dims = [d["state_dim"]] + d["hidden"] + [d["action_dim"]]
    want = []
    for i in range(len(dims) - 1):
        want += [(dims[i], dims[i + 1]), (dims[i + 1],)]
    assert shapes == want
